"""Integration tests for the launch layer: sharding rules, partition
specs, and a real (subprocess) dry-run cell on the 512-device mesh."""
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import LOGICAL_RULES, pspec_for
from repro.models.api import SHAPES, input_specs, supports_shape
from repro.configs import get_config, list_archs

ROOT = os.path.dirname(os.path.dirname(__file__))


def _mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


class TestPspecRules:
    def test_no_duplicate_axes(self):
        mesh = _mesh()
        # expert + moe_mlp must not both claim "model"
        spec = pspec_for(("expert", "embed", "moe_mlp"), mesh, (128, 64, 64))
        flat = [a for part in spec if part for a in
                (part if isinstance(part, tuple) else (part,))]
        assert len(flat) == len(set(flat))

    def test_divisibility_fallback(self):
        # stub 16x16 mesh (pspec_for only reads axis_names + shape)
        class M:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        # 8 kv-heads don't divide a 16-way model axis -> replicated
        spec = pspec_for(("kv_heads",), M(), (8,))
        assert spec in (P(None), P())
        # 64 heads do
        assert pspec_for(("heads",), M(), (64,)) == P("model")

    def test_vocab_in_unsharded(self):
        assert LOGICAL_RULES["vocab_in"] == ()
        assert LOGICAL_RULES["kv_lora"] == ()


class TestInputSpecs:
    @pytest.mark.parametrize("arch", list_archs())
    @pytest.mark.parametrize("shape_name", list(SHAPES))
    def test_specs_are_shape_structs(self, arch, shape_name):
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ok, _ = supports_shape(cfg, shape)
        if not ok:
            pytest.skip("assignment-prescribed skip")
        specs = input_specs(cfg, shape)
        leaves = jax.tree.leaves(specs)
        assert leaves and all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        if shape.kind == "train":
            toks = specs.get("tokens")
            total = toks.shape[0] * (toks.shape[1] + cfg.n_prefix_tokens
                                     if cfg.family == "vlm" else toks.shape[1])
            assert toks.shape[0] == shape.global_batch
        if shape.kind == "decode":
            assert specs["token"].shape == (shape.global_batch, 1)

    def test_long_500k_skips_match_design(self):
        skips = {a for a in list_archs()
                 if not supports_shape(get_config(a), SHAPES["long_500k"])[0]}
        assert skips == {"qwen1.5-110b", "mistral-nemo-12b",
                         "mistral-large-123b", "paligemma-3b",
                         "qwen3-moe-235b-a22b", "seamless-m4t-medium"}


DRYRUN_ONE = f"""
import sys
sys.path.insert(0, {ROOT + "/src"!r})
from repro.launch.dryrun import run_cell
from pathlib import Path
import tempfile, json
with tempfile.TemporaryDirectory() as td:
    rec = run_cell("rwkv6-1.6b", "long_500k", multi_pod=True,
                   out_dir=Path(td))
    assert rec["status"] == "ok", rec
    assert rec["devices"] == 512
    assert rec["memory"]["temp_bytes"] > 0
    print("DRYRUN_OK", rec["compile_s"])
"""


class TestDryrunCell:
    @pytest.mark.slow
    def test_one_cell_on_512_devices(self):
        """Full lower+compile of one cell on the 2x16x16 mesh, in a
        subprocess so the 512-device XLA flag doesn't leak here."""
        r = subprocess.run([sys.executable, "-c", DRYRUN_ONE],
                           capture_output=True, text=True, timeout=420)
        assert "DRYRUN_OK" in r.stdout, r.stdout + r.stderr


class TestArtifacts:
    """The checked-in dry-run artifacts must be complete and green."""

    ART = os.path.join(ROOT, "benchmarks/artifacts/dryrun")

    @pytest.mark.parametrize("mesh", ["pod16x16", "pod2x16x16"])
    def test_sweep_complete_and_green(self, mesh):
        d = os.path.join(self.ART, mesh)
        if not os.path.isdir(d):
            pytest.skip("dry-run artifacts not generated")
        seen = ok = 0
        for arch in list_archs():
            for shape in SHAPES:
                f = os.path.join(d, f"{arch}__{shape}.json")
                assert os.path.exists(f), f"missing cell {arch}/{shape}"
                rec = json.load(open(f))
                seen += 1
                assert rec["status"] in ("ok", "skipped"), (
                    arch, shape, rec.get("error"))
                if rec["status"] == "ok":
                    ok += 1
                    assert rec["cost"]["flops"] > 0
        assert seen == 40 and ok >= 33
