"""Tests for optim / data / checkpoint / runtime / distributed substrates."""
import os
import signal
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint.ckpt import AsyncCheckpointer, latest_step, restore, save
from repro.core.lutq import LutqState
from repro.core.spec import QuantSpec
from repro.data.loader import Prefetcher
from repro.data.synthetic import MarkovLM, shapes_dataset
from repro.data.text import byte_batch, default_corpus
from repro.distributed.compress import ef_int8_transform, init_ef_state
from repro.optim.optimizers import adamw, clip_by_global_norm, cosine_schedule, sgd


class TestOptimizers:
    def _rosenbrock_ish(self):
        target = jnp.asarray([1.0, -2.0, 3.0])

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2) + 0.1 * jnp.sum(p["w"] ** 4)

        return loss

    @pytest.mark.parametrize("opt", [sgd(0.02, momentum=0.9), adamw(0.1)])
    def test_converges_to_stationary_point(self, opt):
        loss = self._rosenbrock_ish()
        params = {"w": jnp.zeros(3), "skip": None}
        state = opt.init(params)
        step = jnp.zeros((), jnp.int32)
        for i in range(300):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params, step + i)
        gnorm = float(jnp.linalg.norm(jax.grad(loss)(params)["w"]))
        # constant-lr Adam hovers near the minimum; 0.05 is well below the
        # O(5) gradient magnitudes away from the basin
        assert gnorm < 5e-2, gnorm

    def test_none_leaves_pass_through(self):
        opt = adamw(0.1)
        params = {"a": jnp.ones(2), "b": None}
        st_ = opt.init(params)
        g = {"a": jnp.ones(2), "b": None}
        p2, _ = opt.update(g, st_, params, jnp.zeros((), jnp.int32))
        assert p2["b"] is None and not jnp.allclose(p2["a"], params["a"])

    def test_clip_by_global_norm(self):
        g = {"a": jnp.ones(4) * 10, "b": None}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert abs(float(gn) - 20.0) < 1e-4
        norm2 = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
        assert abs(float(norm2) - 1.0) < 1e-4

    def test_cosine_schedule(self):
        sch = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
        assert float(sch(jnp.asarray(0))) == 0.0
        assert abs(float(sch(jnp.asarray(10))) - 1.0) < 1e-6
        assert abs(float(sch(jnp.asarray(100))) - 0.1) < 1e-6

    def test_weight_decay_direction(self):
        opt = adamw(0.1, weight_decay=0.5)
        params = {"w": jnp.ones(1) * 4.0}
        st_ = opt.init(params)
        g = {"w": jnp.zeros(1)}
        p2, _ = opt.update(g, st_, params, jnp.zeros((), jnp.int32))
        assert float(p2["w"][0]) < 4.0


class TestCheckpoint:
    def _tree(self):
        return {
            "layer": {"kernel": LutqState(w=jnp.ones((4, 4)),
                                          d=jnp.asarray([0.0, 1.0]),
                                          a=jnp.zeros((4, 4), jnp.int8)),
                      "bias": jnp.arange(4.0)},
            "step": jnp.asarray(7, jnp.int32),
            "missing": None,
        }

    def test_roundtrip(self):
        with tempfile.TemporaryDirectory() as td:
            save(self._tree(), td, 7)
            tree, step = restore(td)
            assert step == 7
            assert isinstance(tree["layer"]["kernel"], LutqState)
            np.testing.assert_array_equal(tree["layer"]["bias"], np.arange(4.0))
            assert tree["missing"] is None
            assert tree["layer"]["kernel"].a.dtype == np.int8

    def test_keep_n_gc(self):
        with tempfile.TemporaryDirectory() as td:
            for s in range(6):
                save({"x": jnp.asarray(s)}, td, s, keep_n=2)
            assert latest_step(td) == 5
            tree, _ = restore(td, 5)
            steps = sorted(os.listdir(td))
            assert len([s for s in steps if not s.endswith(".tmp")]) == 2

    def test_atomicity_partial_invisible(self):
        with tempfile.TemporaryDirectory() as td:
            save({"x": jnp.asarray(1)}, td, 1)
            # a stale tmp dir from a crashed writer must be ignored
            os.makedirs(os.path.join(td, "step_00000009.tmp"))
            assert latest_step(td) == 1

    def test_async_checkpointer(self):
        with tempfile.TemporaryDirectory() as td:
            ck = AsyncCheckpointer(td)
            ck.save(self._tree(), 3)
            ck.wait()
            assert latest_step(td) == 3

    def test_elastic_restore_resharding(self):
        """Restore places arrays with provided shardings (device_put)."""
        with tempfile.TemporaryDirectory() as td:
            save({"w": jnp.arange(8.0)}, td, 1)
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
            tree, _ = restore(td, shardings={"w": NamedSharding(mesh, P())})
            np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(8.0))


class TestData:
    def test_markov_deterministic_and_learnable(self):
        lm = MarkovLM(64, seed=3)
        b1 = lm.batch(0, step=5, batch_size=4, seq_len=16)
        b2 = lm.batch(0, step=5, batch_size=4, seq_len=16)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert lm.entropy_floor() < np.log(64) / 2

    def test_host_sharding_partitions(self):
        lm = MarkovLM(64, seed=3)
        full = lm.batch(0, step=2, batch_size=8, seq_len=8)
        h0 = lm.batch(0, step=2, batch_size=8, seq_len=8, host_id=0, num_hosts=2)
        h1 = lm.batch(0, step=2, batch_size=8, seq_len=8, host_id=1, num_hosts=2)
        np.testing.assert_array_equal(
            np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])

    def test_byte_corpus(self):
        corpus = default_corpus(os.path.dirname(os.path.dirname(__file__)))
        b = byte_batch(corpus, step=3, batch_size=4, seq_len=32)
        assert b["tokens"].shape == (4, 32)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_shapes_dataset_classes(self):
        xs, ys = shapes_dataset(64, seed=0)
        assert xs.shape == (64, 16, 16, 3) and set(np.unique(ys)) <= set(range(8))

    def test_prefetcher_deterministic_order(self):
        pf = Prefetcher(lambda s: {"step": np.asarray(s)}, start_step=10, depth=2)
        got = [next(pf)[0] for _ in range(5)]
        pf.close()
        assert got == [10, 11, 12, 13, 14]


class TestCompression:
    def test_ef_int8_unbiased_over_time(self):
        """Error feedback: sum of compressed grads -> sum of true grads."""
        g = jax.random.normal(jax.random.PRNGKey(0), (128,))
        ef = init_ef_state({"g": g})
        total = jnp.zeros_like(g)
        for i in range(50):
            out, ef = ef_int8_transform({"g": g}, ef)
            total = total + out["g"]
        np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                                   atol=1e-2)

    def test_ef_residual_bounded(self):
        g = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 10
        ef = init_ef_state({"g": g})
        for _ in range(20):
            _, ef = ef_int8_transform({"g": g}, ef)
        scale = float(jnp.max(jnp.abs(g))) / 127
        assert float(jnp.max(jnp.abs(ef["g"]))) <= scale * 2

    @given(st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_property_compression_error_small(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (256,))
        ef = init_ef_state({"g": g})
        out, _ = ef_int8_transform({"g": g}, ef)
        err = jnp.max(jnp.abs(out["g"] - g))
        assert float(err) <= float(jnp.max(jnp.abs(g))) / 127 + 1e-6


RING_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    import sys; sys.path.insert(0, "src")
    from repro.distributed.compress import ring_allreduce

    mesh = jax.make_mesh((8,), ("data",))
    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)

    f = shard_map(lambda s: ring_allreduce(s, "data"), mesh=mesh,
                  in_specs=P("data", None), out_specs=P("data", None))
    out = jax.jit(f)(x)
    expect = jnp.broadcast_to(x.reshape(8, 1, 8).sum(0), (8, 8))
    # each shard holds the full sum of its slice pattern
    ref = jnp.tile(x.reshape(8, 8).sum(0, keepdims=True), (8, 1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
    print("RING_OK")
""")


class TestRingAllreduce:
    def test_ring_on_8_host_devices(self):
        """Runs in a subprocess so the 8-device XLA flag doesn't leak."""
        r = subprocess.run([sys.executable, "-c", RING_TEST],
                           capture_output=True, text=True,
                           cwd=os.path.dirname(os.path.dirname(__file__)))
        assert "RING_OK" in r.stdout, r.stdout + r.stderr


class TestLoop:
    def test_watchdog_flags_stragglers(self):
        from repro.runtime.loop import StragglerWatchdog
        wd = StragglerWatchdog(factor=3.0)
        for _ in range(10):
            wd.observe(0.01)
        assert wd.observe(0.05) and wd.flagged == 1
        assert not wd.observe(0.011)

    def test_loop_resume_continues_step_count(self):
        from repro.runtime.loop import TrainLoop

        def step_fn(state, batch):
            return {"x": state["x"] + 1}, {"loss": jnp.asarray(1.0)}

        with tempfile.TemporaryDirectory() as td:
            loop = TrainLoop(step_fn, lambda s: {}, ckpt_dir=td, ckpt_every=5,
                             log_every=1000)
            state, step = loop.run({"x": jnp.asarray(0)}, 7, handle_signals=False)
            assert step == 7 and int(state["x"]) == 7
            loop2 = TrainLoop(step_fn, lambda s: {}, ckpt_dir=td,
                              ckpt_every=100, log_every=1000)
            state2, step2 = loop2.run({"x": jnp.asarray(0)}, 10,
                                      handle_signals=False)
            assert step2 == 10 and int(state2["x"]) == 10  # resumed from 7
