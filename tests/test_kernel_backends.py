"""Kernel execution-backend layer: lutq_dot parity, backend resolution,
serve_view manifests, and end-to-end serve-mode dispatch."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lutq import LutqState, decode_any, init_state
from repro.core.policy import backend_manifest, quantize_tree, serve_view
from repro.core.rules import QuantPolicy, QuantRule
from repro.core.spec import QuantSpec
from repro.kernels import ops
from repro.kernels.ref import pack4_kin, unpack4_kin


def _serve_state(Kin, N, bits=4, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (Kin, N))
    st = init_state(w, QuantSpec(bits=bits, min_size=1))
    return LutqState(w=None, d=st.d, a=st.a)


# Odd shapes on purpose: none are multiples of the default kernel tiles,
# M=1 is the gemv case, Kin=130/34 are not multiples of bk.
SHAPES = [(1, 34, 50), (5, 96, 72), (33, 130, 57), (8, 64, 211)]


class TestLutqDotParity:
    @pytest.mark.parametrize("M,Kin,N", SHAPES)
    @pytest.mark.parametrize("backend", ["decode", "fused"])
    def test_matches_dense_reference(self, M, Kin, N, backend):
        st = _serve_state(Kin, N)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, Kin))
        want = x @ decode_any(st.d, st.a)
        got = ops.lutq_dot(x, st, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("M,Kin,N", [(1, 34, 50), (5, 96, 72), (8, 64, 211)])
    def test_packed4_matches_reference(self, M, Kin, N):
        st = _serve_state(Kin, N)  # K=16 -> packable
        packed = LutqState(w=None, d=st.d, a=pack4_kin(st.a))
        np.testing.assert_array_equal(np.asarray(unpack4_kin(packed.a)),
                                      np.asarray(st.a))
        x = jax.random.normal(jax.random.PRNGKey(1), (M, Kin))
        want = x @ decode_any(st.d, st.a)
        for backend in ("auto", "packed4", "decode"):
            got = ops.lutq_dot(x, packed, backend=backend)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=2e-4, atol=2e-4, err_msg=backend)

    def test_transposed_tied_logits(self):
        """x @ d[A].T — the tied-embedding readout orientation."""
        st = _serve_state(96, 211)
        x = jax.random.normal(jax.random.PRNGKey(2), (7, 211))
        want = x @ decode_any(st.d, st.a).T
        got = ops.lutq_dot(x, st, backend="fused", transpose_rhs=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_leading_batch_dims_and_dtype(self):
        st = _serve_state(64, 48, bits=2)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 64), jnp.bfloat16)
        got = ops.lutq_dot(x, st, backend="fused")
        assert got.shape == (2, 3, 48) and got.dtype == jnp.bfloat16
        want = jnp.matmul(x, decode_any(st.d, st.a).astype(jnp.bfloat16))
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_stacked_per_channel_falls_back_to_decode(self):
        st = _serve_state(64, 48)
        stk = LutqState(w=None, d=jnp.stack([st.d] * 3),
                        a=jnp.stack([st.a] * 3))
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 64))
        got = ops.lutq_dot(x, stk, backend="fused")  # degrades to decode
        assert got.shape == (3, 4, 48)
        np.testing.assert_allclose(
            np.asarray(got[1]), np.asarray(x @ decode_any(st.d, st.a)),
            rtol=1e-5, atol=1e-5)

    def test_ternary_k3_dictionary(self):
        w = jax.random.normal(jax.random.PRNGKey(5), (64, 40))
        st = init_state(w, QuantSpec(bits=2, constraint="ternary", min_size=1))
        serve = LutqState(w=None, d=st.d, a=st.a)
        x = jax.random.normal(jax.random.PRNGKey(6), (3, 64))
        np.testing.assert_allclose(
            np.asarray(ops.lutq_dot(x, serve, backend="fused")),
            np.asarray(x @ decode_any(st.d, st.a)), rtol=2e-4, atol=2e-4)

    def test_train_form_keeps_ste_gradient(self):
        w = jax.random.normal(jax.random.PRNGKey(7), (32, 16))
        st = init_state(w, QuantSpec(bits=4, min_size=1))
        x = jax.random.normal(jax.random.PRNGKey(8), (4, 32))

        def loss(wm):
            y = ops.lutq_dot(x, LutqState(w=wm, d=st.d, a=st.a),
                             backend="fused")  # train -> decode/STE
            return jnp.sum(y ** 2)

        g = jax.grad(loss)(w)
        # STE: dL/dW == dL/dQ = x^T (2 x Q)
        q = decode_any(st.d, st.a)
        want = x.T @ (2 * (x @ q))
        np.testing.assert_allclose(np.asarray(g), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestResolution:
    def test_auto_rules(self):
        st = _serve_state(64, 48)
        assert ops.resolve_backend(st, "auto") == "fused"
        packed = LutqState(w=None, d=st.d, a=pack4_kin(st.a))
        assert ops.resolve_backend(packed, "auto") == "packed4"
        assert ops.resolve_backend(packed, "auto", transpose_rhs=True) == "decode"
        train = init_state(jax.random.normal(jax.random.PRNGKey(0), (64, 48)),
                           QuantSpec(bits=4, min_size=1))
        assert ops.resolve_backend(train, "fused") == "decode"  # STE
        stacked = LutqState(w=None, d=jnp.stack([st.d] * 2),
                            a=jnp.stack([st.a] * 2))
        assert ops.resolve_backend(stacked, "fused") == "decode"
        assert ops.resolve_backend(stacked, "fused", sliced=True) == "fused"

    def test_explicit_requests_degrade(self):
        st = _serve_state(64, 48)
        # packed4 on an int8 leaf -> fused (no packed layout stored)
        assert ops.resolve_backend(st, "packed4") == "fused"
        assert ops.resolve_backend(st, "decode") == "decode"

    def test_unknown_backend_raises(self):
        st = _serve_state(64, 48)
        with pytest.raises(ValueError, match="unknown backend"):
            ops.resolve_backend(st, "mxu9000")
        with pytest.raises(ValueError):
            ops.lutq_dot(jnp.ones((2, 64)), st, backend="mxu9000")


def _tree():
    k = jax.random.PRNGKey(0)
    return {
        "layers": {
            "attn": {"q": {"kernel": jax.random.normal(k, (64, 64))}},
            "mlp": {"wi": {"kernel": jax.random.normal(k, (64, 128))}},
        },
        "embed": {"table": jax.random.normal(k, (96, 64))},
    }


class TestManifest:
    def test_rule_backend_serialization_roundtrip(self):
        pol = QuantPolicy(rules=(
            QuantRule("*/mlp/*", QuantSpec(bits=4, min_size=1),
                      backend="packed4", name="mlp-p4"),
            QuantRule("*", QuantSpec(bits=4, min_size=1, backend="fused"),
                      name="rest"),
        ), name="be")
        back = QuantPolicy.from_json(pol.to_json())
        assert back == pol
        assert back.rules[0].resolved_backend == "packed4"
        assert back.rules[1].resolved_backend == "fused"  # from the spec

    def test_rule_backend_drives_packing(self):
        pol = QuantPolicy(rules=(
            QuantRule("*/mlp/*", QuantSpec(bits=4, min_size=1),
                      backend="packed4"),
            QuantRule("*", QuantSpec(bits=4, min_size=1), backend="fused"),
        ))
        q = quantize_tree(_tree(), pol)
        sv, man = serve_view(q, policy=pol, with_manifest=True)
        # packed4 rule packs its leaves even without the pack4 flag...
        assert sv["layers"]["mlp"]["wi"]["kernel"].a.dtype == jnp.uint8
        assert man["layers/mlp/wi/kernel"]["backend"] == "packed4"
        # ...and an explicit fused rule keeps int8 even with pack4=True
        sv2 = serve_view(q, pack4=True, policy=pol)
        assert sv2["layers"]["attn"]["q"]["kernel"].a.dtype == jnp.int8

    def test_auto_resolution_roundtrips_through_json(self):
        """backend='auto' resolution recorded by serve_view survives a
        JSON round-trip and matches what lutq_dot resolves per leaf."""
        q = quantize_tree(_tree(), QuantSpec(bits=4, min_size=1))
        sv, man = serve_view(q, pack4=True, with_manifest=True)
        man2 = json.loads(json.dumps(man))
        assert man2 == man
        from repro.nn.tree import tree_paths
        leaves = {"/".join(p): l for p, l in tree_paths(sv)
                  if isinstance(l, LutqState)}
        # "__"-prefixed keys are reserved metadata (e.g. the tuning
        # cache when the process has tuned shapes), not leaf records
        man2 = {k: v for k, v in man2.items() if not k.startswith("__")}
        assert set(man2) == set(leaves)
        for path, rec in man2.items():
            got = ops.resolve_backend(leaves[path], "auto", sliced=True)
            assert got == rec["backend"], path
        # and the standalone manifest of the serve tree agrees
        assert backend_manifest(sv) == man

    def test_manifest_override_matches_forced_dispatch(self):
        q = quantize_tree(_tree(), QuantSpec(bits=4, min_size=1))
        sv = serve_view(q)
        man = backend_manifest(sv, override="decode")
        assert {m["backend"] for m in man.values()} == {"decode"}


ARCHS = ["h2o-danube-1.8b", "mistral-nemo-12b"]


def _serve_setup(arch, **cfg_kw):
    from repro.configs import get_config
    from repro.models import api
    from repro.models.reduce import reduced
    cfg = reduced(get_config(arch)).replace(
        quant=QuantSpec(bits=4, min_size=512), act_bits=32, remat=False,
        **cfg_kw)
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    q = api.quantize(params, cfg, axes)
    sv = serve_view(q, policy=api.resolved_policy(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    return cfg, sv, {"tokens": toks}


class TestServeDispatch:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_fused_matches_decode_logits(self, arch):
        from repro.models import api
        cfg, sv, batch = _serve_setup(arch)
        outs = {}
        for be in ("decode", "fused"):
            logits, _ = api.prefill(sv, cfg.replace(kernel_backend=be), batch)
            outs[be] = np.asarray(logits, np.float32)
        np.testing.assert_allclose(outs["fused"], outs["decode"],
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.slow
    def test_packed4_serve_tree_matches_decode(self):
        from repro.configs import get_config
        from repro.models import api
        from repro.models.reduce import reduced
        cfg = reduced(get_config("mistral-nemo-12b")).replace(
            quant=QuantSpec(bits=4, min_size=512), act_bits=32, remat=False)
        params, axes = api.init(jax.random.PRNGKey(0), cfg)
        q = api.quantize(params, cfg, axes)
        sv = serve_view(q, pack4=True, policy=api.resolved_policy(cfg))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
        outs = {}
        for be in ("decode", "auto"):
            logits, _ = api.prefill(sv, cfg.replace(kernel_backend=be),
                                    {"tokens": toks})
            outs[be] = np.asarray(logits, np.float32)
        np.testing.assert_allclose(outs["auto"], outs["decode"],
                                   rtol=2e-3, atol=2e-3)

    @pytest.mark.slow
    def test_no_dense_materialize_on_fused_path(self, monkeypatch):
        """Acceptance: in serve mode with the fused backend, no matmul
        leaf decodes a dense weight matrix — only gather-style uses
        (the embedding lookup) may."""
        import repro.kernels.ops as ops_mod
        import repro.nn.linear as lin_mod

        calls = []
        real = decode_any

        def counting(d, a):
            calls.append(d.shape)
            return real(d, a)

        monkeypatch.setattr(lin_mod, "decode_any", counting)
        monkeypatch.setattr(ops_mod, "decode_any", counting)
        from repro.models import api
        cfg, sv, batch = _serve_setup("mistral-nemo-12b")

        calls.clear()
        api.prefill(sv, cfg.replace(kernel_backend="fused"), batch)
        fused_calls = len(calls)
        calls.clear()
        api.prefill(sv, cfg.replace(kernel_backend="decode"), batch)
        decode_calls = len(calls)
        # fused path: exactly the embedding gather; decode path: every
        # projection decodes densely.
        assert fused_calls == 1, fused_calls
        assert decode_calls > fused_calls

    def test_generate_backend_kwarg_and_stats(self):
        from repro.runtime.serving import decode_fn, generate
        cfg, sv, batch = _serve_setup("h2o-danube-1.8b")
        out_d = generate(sv, cfg, batch, steps=4, backend="decode")
        out_f, stats = generate(sv, cfg, batch, steps=4, backend="fused",
                                return_stats=True)
        np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_f))
        assert stats["backend"] == "fused" and stats["decode_tok_s"] > 0
        # decode jit is cached per config (no per-call re-wrap)
        c = cfg.replace(kernel_backend="fused")
        assert decode_fn(c) is decode_fn(c)
