"""Degrade hypothesis-based tests to skips when hypothesis is absent.

The container may not ship hypothesis (it is a dev-only dependency, see
requirements-dev.txt). Importing ``given``/``settings``/``st`` from here
instead of from hypothesis keeps collection working either way: with
hypothesis installed the real objects are re-exported; without it,
``@given(...)`` marks the test skipped and everything else no-ops, so
the rest of each module's tests still run.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Stand-in for strategy objects; absorbs any chained call."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
