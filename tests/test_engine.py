"""Continuous-batching engine: ragged-parity suite + lifecycle tests.

The load-bearing contract (ISSUE 3 acceptance): every request served
through the slot-pool engine — admitted mid-flight, decoded next to
unrelated slots, retired early — produces tokens **identical** to a solo
batch=1 ``generate`` of the same prompt. Pinned per family (dense+SWA,
encdec, rwkv, hybrid) and through the fused Pallas LUT-Q backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import serve_view
from repro.core.spec import QuantSpec
from repro.models import api
from repro.models.reduce import reduced
from repro.runtime.engine import Engine, synthetic_requests
from repro.runtime.serving import generate


def _fp_setup(arch):
    cfg = reduced(get_config(arch)).replace(quant=None, act_bits=32,
                                            remat=False)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _solo(params, cfg, toks, steps, max_len, **kw):
    return np.asarray(
        generate(params, cfg, {"tokens": jnp.asarray(toks[None])},
                 steps=steps, max_len=max_len, **kw))[0]


LENS = [6, 14, 9, 11]  # ragged on purpose; more requests than slots


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["h2o-danube-1.8b",   # dense + SWA ring
                                  "mistral-nemo-12b",  # dense GQA, no window
                                  "rwkv6-1.6b",        # recurrent state
                                  "zamba2-2.7b"])      # hybrid mamba+attn
def test_engine_ragged_parity(arch):
    """Mixed-length requests through a 2-slot engine (forcing slot reuse
    and mid-flight admission) decode token-identically to solo runs."""
    cfg, params = _fp_setup(arch)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (4, 14),
                                         0, cfg.vocab), np.int32)
    G, max_len = 4, 20
    eng = Engine(params, cfg, capacity=2, max_len=max_len)
    for i, L in enumerate(LENS):
        eng.submit(toks[i, :L], max_new=G)
    res = eng.run()
    assert [r["rid"] for r in res] == [0, 1, 2, 3]
    for i, L in enumerate(LENS):
        want = _solo(params, cfg, toks[i, :L], G, max_len)
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"{arch} request {i}")


@pytest.mark.slow
def test_engine_ragged_parity_encdec():
    """Encdec requests carry their own ragged source frames; the decode
    cross-attention must mask the slot pool's zero padding."""
    cfg, params = _fp_setup("seamless-m4t-medium")
    rng = jax.random.PRNGKey(7)
    frames = [np.asarray(jax.random.normal(jax.random.fold_in(rng, i),
                                           (s, cfg.d_model)), np.float32)
              for i, s in enumerate([10, 6, 13])]
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 12),
                                         0, cfg.vocab), np.int32)
    lens, G, max_len = [5, 12, 8], 4, 18
    eng = Engine(params, cfg, capacity=2, max_len=max_len, src_len=13)
    for i, L in enumerate(lens):
        eng.submit(toks[i, :L], max_new=G, frames=frames[i])
    res = eng.run()
    for i, L in enumerate(lens):
        want = np.asarray(generate(
            params, cfg, {"tokens": jnp.asarray(toks[i:i + 1, :L]),
                          "frames": jnp.asarray(frames[i][None])},
            steps=G, max_len=max_len))[0]
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"encdec request {i}")


@pytest.mark.slow
def test_engine_ragged_parity_fused_backend():
    """Parity holds on serve-form LUT-Q weights through the fused Pallas
    kernel backend — the configuration the engine exists to serve."""
    cfg = reduced(get_config("h2o-danube-1.8b")).replace(
        quant=QuantSpec(bits=4, min_size=256), act_bits=8, remat=False)
    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    sv = serve_view(api.quantize(params, cfg, axes),
                    policy=api.resolved_policy(cfg))
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 14),
                                         0, cfg.vocab), np.int32)
    lens, G, max_len = [6, 14, 9], 4, 20
    eng = Engine(sv, cfg, capacity=2, max_len=max_len, backend="fused")
    for i, L in enumerate(lens):
        eng.submit(toks[i, :L], max_new=G)
    res = eng.run()
    assert eng.stats()["backend"] == "fused"
    for i, L in enumerate(lens):
        want = _solo(sv, cfg, toks[i, :L], G, max_len, backend="fused")
        np.testing.assert_array_equal(res[i]["tokens"], want,
                                      err_msg=f"fused request {i}")


@pytest.mark.slow
def test_engine_vlm_prefix_positions_vs_oracle():
    """The vlm modality prefix occupies cache slots: the engine's adapt
    lengths must count prefix + text, or decode overwrites live KV
    (caught in review — parity alone can't see it because generate
    shares the path, so pin against the teacher-forced full-prefill
    oracle)."""
    cfg, params = _fp_setup("paligemma-3b")
    B, P, G = 2, 6, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    pe = jax.random.normal(jax.random.PRNGKey(3),
                           (B, cfg.n_prefix_tokens, cfg.d_model), cfg.dtype)
    cur, want = toks, []
    for _ in range(G):
        lg, _ = api.prefill(params, cfg, {"tokens": cur, "prefix_embeds": pe})
        nxt = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
        want.append(nxt)
        cur = jnp.concatenate([cur, nxt], 1)
    want = np.asarray(jnp.concatenate(want, 1))

    got = np.asarray(generate(params, cfg,
                              {"tokens": toks, "prefix_embeds": pe},
                              steps=G, max_len=P + G))
    np.testing.assert_array_equal(got, want)

    eng = Engine(params, cfg, capacity=2, max_len=P + G)
    for i in range(B):
        eng.submit(np.asarray(toks)[i], max_new=G,
                   prefix_embeds=np.asarray(pe)[i])
    for i, r in enumerate(eng.run()):
        np.testing.assert_array_equal(r["tokens"], want[i])

    # text-only requests on a vlm config occupy NO prefix slots — the
    # engine must not shift their cache lengths
    cur, want_t = toks, []
    for _ in range(G):
        lg, _ = api.prefill(params, cfg, {"tokens": cur})
        nxt = jnp.argmax(lg[:, -1].astype(jnp.float32), -1)[:, None].astype(jnp.int32)
        want_t.append(nxt)
        cur = jnp.concatenate([cur, nxt], 1)
    want_t = np.asarray(jnp.concatenate(want_t, 1))
    got_t = np.asarray(generate(params, cfg, {"tokens": toks},
                                steps=G, max_len=P + G))
    np.testing.assert_array_equal(got_t, want_t)


class TestEngineLifecycle:
    def test_fifo_slot_reuse_and_stats(self):
        cfg, params = _fp_setup("h2o-danube-1.8b")
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (5, 10),
                                             0, cfg.vocab), np.int32)
        eng = Engine(params, cfg, capacity=2, max_len=16)
        for i in range(5):
            eng.submit(toks[i, :4 + i], max_new=2 + i % 3)
        res = eng.run()
        st = eng.stats()
        assert st["admitted"] == st["completed"] == 5
        assert all(r["finish"] == "length" for r in res)
        assert [r["n_new"] for r in res] == [2 + i % 3 for i in range(5)]
        assert st["decode_tok_s"] > 0 and st["goodput_tok_s"] > 0
        assert st["p95_latency_s"] >= st["p50_latency_s"] > 0
        assert eng.idle

    def test_eos_retires_slot_immediately(self):
        cfg, params = _fp_setup("h2o-danube-1.8b")
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (1, 8),
                                             0, cfg.vocab), np.int32)
        solo = _solo(params, cfg, toks[0], 6, 20)
        eos = int(solo[2])
        eng = Engine(params, cfg, capacity=1, max_len=20)
        eng.submit(toks[0], max_new=6, eos_id=eos)
        r = eng.run()[0]
        assert r["finish"] == "eos" and r["n_new"] == 3
        np.testing.assert_array_equal(r["tokens"], solo[:3])

    def test_streaming_yields_in_retirement_order(self):
        cfg, params = _fp_setup("h2o-danube-1.8b")
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                             0, cfg.vocab), np.int32)
        eng = Engine(params, cfg, capacity=2, max_len=16)
        eng.submit(toks[0], max_new=6)
        eng.submit(toks[1], max_new=2)   # retires first despite rid order
        order = [r["rid"] for r in eng.run(stream=True)]
        assert order == [1, 0]

    def test_submit_validation(self):
        cfg, params = _fp_setup("h2o-danube-1.8b")
        eng = Engine(params, cfg, capacity=1, max_len=8)
        with pytest.raises(ValueError):
            eng.submit(np.arange(6, dtype=np.int32), max_new=4)  # 6+4 > 8
        with pytest.raises(ValueError):
            eng.submit(np.zeros(0, np.int32), max_new=1)

    def test_synthetic_requests_deterministic(self):
        cfg, _ = _fp_setup("h2o-danube-1.8b")
        a = synthetic_requests(cfg, 5, max_prompt=12, max_new=8, seed=3,
                               rate=2.0)
        b = synthetic_requests(cfg, 5, max_prompt=12, max_new=8, seed=3,
                               rate=2.0)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x["tokens"], y["tokens"])
            assert x["max_new"] == y["max_new"]
            assert x["arrival_s"] == y["arrival_s"]
        assert a[0]["arrival_s"] == 0.0
        assert all(x["arrival_s"] < y["arrival_s"]
                   for x, y in zip(a, a[1:]))


class TestGenerateWrapper:
    def test_generate_matches_engine_preload(self):
        """generate is a thin wrapper: same trace, same tokens as a
        manual preload + run."""
        cfg, params = _fp_setup("h2o-danube-1.8b")
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
        got = np.asarray(generate(params, cfg, {"tokens": toks}, steps=4,
                                  max_len=12))
        eng = Engine(params, cfg, capacity=2, max_len=12)
        eng.preload({"tokens": toks}, 4)
        res = eng.run()
        for i in range(2):
            np.testing.assert_array_equal(got[i], res[i]["tokens"])

    def test_generate_eos_pads_output(self):
        cfg, params = _fp_setup("h2o-danube-1.8b")
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
        solo = np.asarray(generate(params, cfg, {"tokens": toks}, steps=6,
                                   max_len=20))[0]
        eos = int(solo[2])
        got = np.asarray(generate(params, cfg, {"tokens": toks}, steps=6,
                                  max_len=20, eos_id=eos))[0]
        np.testing.assert_array_equal(got[:3], solo[:3])
        assert (got[3:] == eos).all()

    def test_generate_ragged_ssm_routes_through_admission(self):
        """Ragged rwkv batches cannot use a padded batched prefill (the
        recurrent state would integrate the padding) — generate must
        still be exact via per-request admission."""
        cfg, params = _fp_setup("rwkv6-1.6b")
        toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 12),
                                             0, cfg.vocab), np.int32)
        # uniformly-short lengths are padding too (caught in review:
        # min==max must not skip the exact-length route)
        for lens in ([5, 12], [5, 5]):
            G = 4
            padded = np.zeros((2, 12), np.int32)
            for i, L in enumerate(lens):
                padded[i, :L] = toks[i, :L]
            rag = np.asarray(generate(params, cfg,
                                      {"tokens": jnp.asarray(padded)},
                                      steps=G, lengths=lens, max_len=16))
            for i, L in enumerate(lens):
                want = _solo(params, cfg, toks[i, :L], G, 16)
                np.testing.assert_array_equal(rag[i], want,
                                              err_msg=f"lens={lens} i={i}")
