"""Tests for multiplier-less batch normalization (paper Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_norm, fake_quant, inference_scale_offset, init_bn, relu_fake_quant
from repro.core.actquant import learned_clip_fake_quant
from repro.core.mlbn import apply_scale_offset_shift


def _is_pow2(a, tol=1e-6):
    a = np.abs(np.asarray(a))
    a = a[a > 0]
    e = np.log2(a)
    return np.allclose(e, np.round(e), atol=tol)


class TestMLBN:
    def test_training_normalizes(self):
        p, s = init_bn(16)
        x = jax.random.normal(jax.random.PRNGKey(0), (128, 16)) * 5 + 3
        y, _ = batch_norm(x, p, s, training=True, multiplier_less=False)
        np.testing.assert_allclose(np.asarray(jnp.mean(y, 0)), 0, atol=1e-4)
        np.testing.assert_allclose(np.asarray(jnp.std(y, 0)), 1, atol=1e-2)

    def test_inference_scale_is_pow2(self):
        p, s = init_bn(16)
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 16)) * 2 + 1
        _, s2 = batch_norm(x, p, s, training=True, multiplier_less=True, momentum=0.0)
        a, b = inference_scale_offset(p, s2, multiplier_less=True)
        assert _is_pow2(a)

    def test_mlbn_close_to_bn(self):
        """Pow2-quantized scale stays within 2x of true scale => output
        error bounded; on normalized stats they should be close."""
        p, s = init_bn(8)
        x = jax.random.normal(jax.random.PRNGKey(1), (512, 8)) * 1.7 - 0.4
        y_bn, _ = batch_norm(x, p, s, training=True, multiplier_less=False)
        y_ml, _ = batch_norm(x, p, s, training=True, multiplier_less=True)
        # scale rounding error <= sqrt(2) factor
        ratio = np.asarray(jnp.std(y_ml, 0) / jnp.std(y_bn, 0))
        assert np.all(ratio <= np.sqrt(2) + 1e-3) and np.all(ratio >= 1 / np.sqrt(2) - 1e-3)

    def test_gamma_receives_gradient_through_ste(self):
        p, s = init_bn(4)
        x = jax.random.normal(jax.random.PRNGKey(2), (64, 4))

        def loss(gamma):
            y, _ = batch_norm(x, p._replace(gamma=gamma), s, training=True, multiplier_less=True)
            return jnp.sum(y ** 2)

        g = jax.grad(loss)(p.gamma)
        assert np.all(np.isfinite(np.asarray(g))) and np.any(np.asarray(g) != 0)

    def test_inference_matches_folded_form(self):
        p, s = init_bn(8)
        x = jax.random.normal(jax.random.PRNGKey(3), (32, 8)) * 2
        _, s2 = batch_norm(x, p, s, training=True, momentum=0.0)
        y_inf, _ = batch_norm(x, p, s2, training=False, multiplier_less=True)
        a, b = inference_scale_offset(p, s2, multiplier_less=True)
        np.testing.assert_allclose(np.asarray(y_inf), np.asarray(a * x + b), rtol=1e-4, atol=1e-5)

    def test_shift_add_apply_bitwise_equals_multiply(self):
        """The serve form — ldexp exponent-add on a sign-flipped x — is
        bit-identical to a*x+b for the exact-pow2 folded scale."""
        p, s = init_bn(8)
        gamma = p.gamma * jnp.linspace(0.3, 4.0, 8)
        p = p._replace(gamma=gamma,
                       beta=jax.random.normal(jax.random.PRNGKey(4), (8,)))
        x = jax.random.normal(jax.random.PRNGKey(5), (32, 8)) * 2
        _, s2 = batch_norm(x, p, s, training=True, momentum=0.0)
        a, b = inference_scale_offset(p, s2, multiplier_less=True)
        np.testing.assert_array_equal(
            np.asarray(apply_scale_offset_shift(x, a, b)),
            np.asarray(a * x + b))

    def test_shift_add_zero_scale(self):
        a = jnp.array([0.0, 2.0])
        b = jnp.array([1.0, -1.0])
        x = jnp.ones((3, 2))
        np.testing.assert_array_equal(
            np.asarray(apply_scale_offset_shift(x, a, b)),
            np.asarray(a * x + b))

    def test_resnet_serve_path_matches_trained_mlbn_forward(self):
        """resnet20's multiplier_less inference (shift+add fold) is
        bit-identical to the BN-module forward it replaces."""
        from repro.core.mlbn import BNStats
        from repro.models.resnet import init_resnet20, resnet20_apply
        params, stats = init_resnet20(jax.random.PRNGKey(0), widths=(8, 16),
                                      blocks=1, n_classes=4)
        # non-trivial running stats so the fold actually does work
        stats = jax.tree.map(lambda s: s, stats)
        stats = {k: BNStats(v.mean + 0.3, v.var * 2.5) for k, v in stats.items()}
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
        y_fold, _ = resnet20_apply(params, stats, x, widths=(8, 16), blocks=1,
                                   training=False, multiplier_less=True)
        # reference: the training-form module path with multiplier_less
        # (same pow2-rounded scale), inference stats
        def bn_ref(p, s, h):
            y, _ = batch_norm(h, p["p"], s, training=False,
                              multiplier_less=True)
            return y
        from repro.models import resnet as resnet_mod
        orig = apply_scale_offset_shift
        try:
            resnet_mod.apply_scale_offset_shift = \
                lambda h, a, b, **kw: a.reshape((1,) * (h.ndim - 1) + (-1,)) * h \
                + b.reshape((1,) * (h.ndim - 1) + (-1,))
            y_mul, _ = resnet20_apply(params, stats, x, widths=(8, 16),
                                      blocks=1, training=False,
                                      multiplier_less=True)
        finally:
            resnet_mod.apply_scale_offset_shift = orig
        np.testing.assert_array_equal(np.asarray(y_fold), np.asarray(y_mul))


class TestActQuant:
    def test_fake_quant_levels(self):
        x = jnp.linspace(-1, 1, 1001)
        q = fake_quant(x, bits=8)
        assert len(np.unique(np.asarray(q))) <= 256

    def test_fake_quant_identity_gradient(self):
        x = jnp.linspace(-1, 1, 101)
        g = jax.grad(lambda x: jnp.sum(fake_quant(x, 8) ** 2))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(2 * fake_quant(x, 8)), atol=1e-6)

    def test_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q = fake_quant(x, bits=8)
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        assert float(jnp.max(jnp.abs(q - x))) <= scale * 0.5 + 1e-7

    def test_relu_variant_nonnegative(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1000,))
        q = relu_fake_quant(x, bits=8)
        assert float(jnp.min(q)) >= 0.0

    def test_bits32_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (100,))
        np.testing.assert_array_equal(np.asarray(fake_quant(x, 32)), np.asarray(x))

    def test_learned_clip_levels_and_range(self):
        x = jnp.linspace(-3, 3, 1001)
        q = learned_clip_fake_quant(x, jnp.float32(1.0), bits=4)
        assert len(np.unique(np.asarray(q))) <= 16
        assert float(jnp.max(jnp.abs(q))) <= 1.0 + 1e-5

    def test_learned_clip_alpha_receives_gradient(self):
        """PACT-style clip: gradient reaches alpha through the clip
        boundary (zero inside the range, +/-1-ish at saturation)."""
        x = jnp.array([-0.2, 0.3, 4.0, 5.0])

        def loss(alpha):
            return jnp.sum(learned_clip_fake_quant(x, alpha, bits=8))

        # two elements saturate the high clip: d/dalpha of clip(x,-a,a)
        # is +1 there, 0 inside the range -> dL/dalpha == 2
        g = float(jax.grad(loss)(jnp.float32(1.0)))
        np.testing.assert_allclose(g, 2.0, atol=1e-5)

    def test_learned_clip_identity_gradient_inside_range(self):
        x = jnp.linspace(-0.5, 0.5, 11)
        g = jax.grad(lambda x: jnp.sum(
            learned_clip_fake_quant(x, jnp.float32(1.0), bits=8)))(x)
        np.testing.assert_allclose(np.asarray(g), 1.0, atol=1e-6)
