"""Tests for multiplier-less batch normalization (paper Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_norm, fake_quant, inference_scale_offset, init_bn, relu_fake_quant


def _is_pow2(a, tol=1e-6):
    a = np.abs(np.asarray(a))
    a = a[a > 0]
    e = np.log2(a)
    return np.allclose(e, np.round(e), atol=tol)


class TestMLBN:
    def test_training_normalizes(self):
        p, s = init_bn(16)
        x = jax.random.normal(jax.random.PRNGKey(0), (128, 16)) * 5 + 3
        y, _ = batch_norm(x, p, s, training=True, multiplier_less=False)
        np.testing.assert_allclose(np.asarray(jnp.mean(y, 0)), 0, atol=1e-4)
        np.testing.assert_allclose(np.asarray(jnp.std(y, 0)), 1, atol=1e-2)

    def test_inference_scale_is_pow2(self):
        p, s = init_bn(16)
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 16)) * 2 + 1
        _, s2 = batch_norm(x, p, s, training=True, multiplier_less=True, momentum=0.0)
        a, b = inference_scale_offset(p, s2, multiplier_less=True)
        assert _is_pow2(a)

    def test_mlbn_close_to_bn(self):
        """Pow2-quantized scale stays within 2x of true scale => output
        error bounded; on normalized stats they should be close."""
        p, s = init_bn(8)
        x = jax.random.normal(jax.random.PRNGKey(1), (512, 8)) * 1.7 - 0.4
        y_bn, _ = batch_norm(x, p, s, training=True, multiplier_less=False)
        y_ml, _ = batch_norm(x, p, s, training=True, multiplier_less=True)
        # scale rounding error <= sqrt(2) factor
        ratio = np.asarray(jnp.std(y_ml, 0) / jnp.std(y_bn, 0))
        assert np.all(ratio <= np.sqrt(2) + 1e-3) and np.all(ratio >= 1 / np.sqrt(2) - 1e-3)

    def test_gamma_receives_gradient_through_ste(self):
        p, s = init_bn(4)
        x = jax.random.normal(jax.random.PRNGKey(2), (64, 4))

        def loss(gamma):
            y, _ = batch_norm(x, p._replace(gamma=gamma), s, training=True, multiplier_less=True)
            return jnp.sum(y ** 2)

        g = jax.grad(loss)(p.gamma)
        assert np.all(np.isfinite(np.asarray(g))) and np.any(np.asarray(g) != 0)

    def test_inference_matches_folded_form(self):
        p, s = init_bn(8)
        x = jax.random.normal(jax.random.PRNGKey(3), (32, 8)) * 2
        _, s2 = batch_norm(x, p, s, training=True, momentum=0.0)
        y_inf, _ = batch_norm(x, p, s2, training=False, multiplier_less=True)
        a, b = inference_scale_offset(p, s2, multiplier_less=True)
        np.testing.assert_allclose(np.asarray(y_inf), np.asarray(a * x + b), rtol=1e-4, atol=1e-5)


class TestActQuant:
    def test_fake_quant_levels(self):
        x = jnp.linspace(-1, 1, 1001)
        q = fake_quant(x, bits=8)
        assert len(np.unique(np.asarray(q))) <= 256

    def test_fake_quant_identity_gradient(self):
        x = jnp.linspace(-1, 1, 101)
        g = jax.grad(lambda x: jnp.sum(fake_quant(x, 8) ** 2))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(2 * fake_quant(x, 8)), atol=1e-6)

    def test_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q = fake_quant(x, bits=8)
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        assert float(jnp.max(jnp.abs(q - x))) <= scale * 0.5 + 1e-7

    def test_relu_variant_nonnegative(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1000,))
        q = relu_fake_quant(x, bits=8)
        assert float(jnp.min(q)) >= 0.0

    def test_bits32_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (100,))
        np.testing.assert_array_equal(np.asarray(fake_quant(x, 32)), np.asarray(x))
