"""QuantPolicy: rule resolution, uniform equivalence with the legacy
global-QuantSpec behavior, mixed-policy train/checkpoint/serve roundtrip,
and JSON serialization."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lutq import LutqState, decode_any, init_state
from repro.core.policy import (
    _vmapped,
    default_predicate,
    effective_bits,
    kmeans_tree,
    merge_trainable,
    quantize_tree,
    quantized_fraction,
    rule_breakdown,
    serve_view,
    split_trainable,
)
from repro.core.rules import (
    QuantPolicy,
    QuantRule,
    as_policy,
    get_policy,
    mixed_paper,
    paper_default,
    serving_aggressive,
    uniform,
)
from repro.core.spec import (
    LUTQ_2BIT,
    LUTQ_4BIT,
    LUTQ_4BIT_POW2,
    TERNARY_SCALED,
    QuantSpec,
)
from repro.nn.tree import tree_paths


def _params():
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 6)
    return {
        "embed": {"table": jax.random.normal(ks[0], (64, 64))},
        "layers": {
            "attn": {"q": {"kernel": jax.random.normal(ks[1], (2, 64, 64))}},
            "mlp": {"wi": {"kernel": jax.random.normal(ks[2], (2, 64, 96))}},
            "ln1": {"scale": jnp.ones((2, 64))},
        },
        "lm_head": {"kernel": jax.random.normal(ks[3], (64, 64))},
        "step": jnp.zeros((), jnp.int32),
    }


class TestRuleResolution:
    def test_first_match_wins(self):
        pol = QuantPolicy(rules=(
            QuantRule("*/attn/*", LUTQ_2BIT, name="narrow"),
            QuantRule("*", LUTQ_4BIT, name="wide"),
        ))
        rid, spec = pol.resolve(("layers", "attn", "q", "kernel"), size=10**6)
        assert rid == 0 and spec is LUTQ_2BIT
        rid, spec = pol.resolve(("layers", "mlp", "wi", "kernel"), size=10**6)
        assert rid == 1 and spec is LUTQ_4BIT
        # order flipped: the catch-all claims everything
        pol2 = QuantPolicy(rules=tuple(reversed(pol.rules)))
        rid, spec = pol2.resolve(("layers", "attn", "q", "kernel"), size=10**6)
        assert rid == 0 and spec is LUTQ_4BIT

    def test_exclusion_rule_stops_matching(self):
        pol = QuantPolicy(rules=(
            QuantRule("re:(^|/)table$", None, name="embed-fp"),
            QuantRule("*", LUTQ_4BIT, name="all"),
        ))
        rid, spec = pol.resolve(("embed", "table"), size=10**6)
        assert rid == 0 and spec is None  # claimed, excluded — not rule 1

    def test_per_rule_min_size_floor(self):
        pol = QuantPolicy(rules=(
            QuantRule("*/attn/*", LUTQ_4BIT, min_size=10**9, name="floored"),
            QuantRule("*", LUTQ_4BIT, name="all"),
        ))
        # under the floor: rule 0 still claims the leaf (no fallthrough)
        rid, spec = pol.resolve(("layers", "attn", "q", "kernel"), size=128)
        assert rid == 0 and spec is None
        q = quantize_tree(_params(), pol)
        assert not isinstance(q["layers"]["attn"]["q"]["kernel"], LutqState)
        assert isinstance(q["layers"]["mlp"]["wi"]["kernel"], LutqState)

    def test_regex_pattern(self):
        r = QuantRule("re:(^|/)table$", None)
        assert r.matches(("embed", "table"))
        assert r.matches(("table",))
        assert not r.matches(("embed", "table2"))
        assert not r.matches(("ctable",))

    def test_unmatched_leaf_stays_fp(self):
        pol = QuantPolicy(rules=(QuantRule("*/attn/*", LUTQ_4BIT),))
        q = quantize_tree(_params(), pol)
        assert isinstance(q["layers"]["attn"]["q"]["kernel"], LutqState)
        assert not isinstance(q["layers"]["mlp"]["wi"]["kernel"], LutqState)
        assert not isinstance(q["embed"]["table"], LutqState)


class TestUniformEquivalence:
    """A bare QuantSpec must reproduce the legacy behavior bit-identically."""

    def test_bare_spec_equals_uniform_policy(self):
        spec = QuantSpec(bits=4, constraint="pow2", min_size=1024)
        qa = quantize_tree(_params(), spec)
        qb = quantize_tree(_params(), uniform(spec))
        for (pa, la), (_, lb) in zip(tree_paths(qa), tree_paths(qb)):
            assert isinstance(la, LutqState) == isinstance(lb, LutqState), pa
            if isinstance(la, LutqState):
                np.testing.assert_array_equal(np.asarray(la.d), np.asarray(lb.d))
                np.testing.assert_array_equal(np.asarray(la.a), np.asarray(lb.a))

    def test_bit_identical_with_seed_semantics(self):
        """Replicates the pre-policy inline logic (predicate + min_size +
        vmapped init_state) and checks d/a match exactly."""
        spec = QuantSpec(bits=2, min_size=1024)
        params = _params()
        q = quantize_tree(params, spec)
        for path, leaf in tree_paths(params):
            got = q
            for kk in path:
                got = got[kk]
            eligible = (default_predicate(path, leaf)
                        and hasattr(leaf, "size") and leaf.size >= spec.min_size)
            assert isinstance(got, LutqState) == eligible, path
            if eligible:
                nstack = max(0, leaf.ndim - 2)
                want = _vmapped(lambda w: init_state(w, spec), nstack)(leaf)
                np.testing.assert_array_equal(np.asarray(got.d), np.asarray(want.d))
                np.testing.assert_array_equal(np.asarray(got.a), np.asarray(want.a))

    def test_kmeans_tree_accepts_bare_spec(self):
        spec = QuantSpec(bits=2, min_size=1024, kmeans_iters=2)
        q = quantize_tree(_params(), spec)
        q2 = kmeans_tree(q, spec)
        st = q2["layers"]["mlp"]["wi"]["kernel"]
        assert st.d.shape == (2, 4)


class TestMixedPolicyEndToEnd:
    def _mixed(self, min_size=512):
        return QuantPolicy(rules=(
            QuantRule("re:(^|/)table$", None, name="first-layer-fp"),
            QuantRule("lm_head/*", None, name="last-layer-fp"),
            QuantRule("*/attn/*", LUTQ_4BIT_POW2, min_size=min_size,
                      name="attn-4bit-pow2"),
            QuantRule("*/mlp/*", TERNARY_SCALED, min_size=min_size,
                      name="mlp-ternary"),
        ), name="test_mixed")

    def test_per_leaf_specs_applied(self):
        pol = self._mixed()
        q = quantize_tree(_params(), pol)
        attn = q["layers"]["attn"]["q"]["kernel"]
        mlp = q["layers"]["mlp"]["wi"]["kernel"]
        assert attn.d.shape == (2, 16) and attn.sid.shape == (2,)
        assert set(np.asarray(attn.sid).tolist()) == {2}
        assert mlp.d.shape == (2, 3)
        assert set(np.asarray(mlp.sid).tolist()) == {3}
        assert not isinstance(q["embed"]["table"], LutqState)
        assert not isinstance(q["lm_head"]["kernel"], LutqState)
        # pow2 constraint honored per-leaf: nonzero entries are 2^k
        d = np.asarray(attn.d).ravel()
        nz = d[d != 0]
        np.testing.assert_allclose(np.log2(np.abs(nz)),
                                   np.round(np.log2(np.abs(nz))), atol=1e-6)
        # ternary: per-slice {-a, 0, a}
        dm = np.asarray(mlp.d)
        np.testing.assert_allclose(dm[:, 1], 0.0, atol=1e-7)
        np.testing.assert_allclose(dm[:, 0], -dm[:, 2], rtol=1e-5)

    def test_kmeans_refresh_honors_each_rule(self):
        pol = self._mixed()
        q = quantize_tree(_params(), pol)
        # perturb masters and refresh
        q["layers"]["mlp"]["wi"]["kernel"] = q["layers"]["mlp"]["wi"]["kernel"]._replace(
            w=q["layers"]["mlp"]["wi"]["kernel"].w * 2.0)
        q2 = kmeans_tree(q, pol)
        attn2 = q2["layers"]["attn"]["q"]["kernel"]
        mlp2 = q2["layers"]["mlp"]["wi"]["kernel"]
        assert attn2.d.shape == (2, 16)  # still 4-bit
        dm = np.asarray(mlp2.d)
        np.testing.assert_allclose(dm[:, 1], 0.0, atol=1e-7)  # still ternary
        assert set(np.asarray(mlp2.sid).tolist()) == {3}  # rule id survives
        # ternary scale tracked the doubled masters
        d0 = np.asarray(q["layers"]["mlp"]["wi"]["kernel"].d)
        assert float(np.abs(dm[:, 2]).mean()) > float(np.abs(d0[:, 2]).mean())

    def test_split_merge_preserves_sid(self):
        q = quantize_tree(_params(), self._mixed())
        t, s = split_trainable(q)
        assert "__lutq_sid" in s["layers"]["attn"]["q"]["kernel"]
        back = merge_trainable(t, s)
        assert set(np.asarray(back["layers"]["attn"]["q"]["kernel"].sid).tolist()) == {2}

    @pytest.mark.slow
    def test_train_ckpt_restore_serve_roundtrip(self, tmp_path):
        """The acceptance-criteria path: mixed quantize -> train step
        (per-leaf refresh) -> checkpoint save/restore (policy included)
        -> serve_view."""
        from repro.checkpoint import ckpt
        from repro.configs import get_config
        from repro.models import api
        from repro.models.reduce import reduced
        from repro.optim.optimizers import adamw
        from repro.optim.train_state import (init_train_state, make_train_step,
                                             state_flat)

        pol = self._mixed(min_size=256)
        cfg = reduced(get_config("h2o-danube-1.8b")).replace(
            vocab=64, quant=pol, act_bits=8)
        params, axes = api.init(jax.random.PRNGKey(0), cfg)
        qparams = api.quantize(params, cfg, axes)
        attn = qparams["layers"]["attn"]["q"]["kernel"]
        mlp = qparams["layers"]["mlp"]["wi"]["kernel"]
        assert attn.d.shape[-1] == 16 and mlp.d.shape[-1] == 3

        opt = adamw(1e-3)
        state = state_flat(init_train_state(qparams, opt))
        step = jax.jit(make_train_step(cfg, api.loss_fn, opt))
        batch = {"tokens": jnp.zeros((2, 16), jnp.int32) + 3,
                 "labels": jnp.ones((2, 16), jnp.int32)}
        state, metrics = step(state, batch)
        state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))

        # refreshed static still honors per-rule specs
        merged = merge_trainable(state["trainable"], state["static"])
        mlp2 = merged["layers"]["mlp"]["wi"]["kernel"]
        assert mlp2.d.shape[-1] == 3
        np.testing.assert_allclose(np.asarray(mlp2.d)[..., 1], 0.0, atol=1e-7)

        # checkpoint roundtrip with the policy in the manifest
        ckpt.save(state, str(tmp_path), 2, policy=pol)
        restored, rstep = ckpt.restore(str(tmp_path))
        assert rstep == 2
        rpol = ckpt.load_policy(str(tmp_path))
        assert rpol == pol
        rmerged = merge_trainable(restored["trainable"], restored["static"])
        for (pa, la), (_, lb) in zip(tree_paths(merged), tree_paths(rmerged)):
            if isinstance(la, LutqState):
                assert isinstance(lb, LutqState), pa
                np.testing.assert_array_equal(np.asarray(la.d), np.asarray(lb.d))
                np.testing.assert_array_equal(np.asarray(la.a), np.asarray(lb.a))
                assert (la.sid is None) == (lb.sid is None)

        # serve view from the restored tree, policy-gated packing
        from repro.kernels.ref import unpack4_kin
        sv = serve_view(rmerged, pack4=True, policy=rpol)
        smlp = sv["layers"]["mlp"]["wi"]["kernel"]
        assert sv["layers"]["attn"]["q"]["kernel"].w is None
        sa = unpack4_kin(smlp.a) if smlp.a.dtype == jnp.uint8 else smlp.a
        np.testing.assert_array_equal(np.asarray(decode_any(smlp.d, sa)),
                                      np.asarray(decode_any(mlp2.d, mlp2.a)))
        # a decode forward runs on the serve tree
        logits, _ = api.prefill(sv, cfg, {"tokens": batch["tokens"]})
        assert np.isfinite(np.asarray(logits)).all()

    def test_legacy_checkpoint_without_sid_restores(self, tmp_path):
        """Checkpoints written before sid existed (3-field LutqState)
        still load; sid comes back None."""
        from repro.checkpoint import ckpt
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        stripped = jax.tree.map(
            lambda x: x, q,
            is_leaf=lambda x: isinstance(x, LutqState))

        def strip(x):
            if isinstance(x, LutqState):
                return LutqState(w=x.w, d=x.d, a=x.a)
            return x
        from repro.nn.tree import map_with_path
        stripped = map_with_path(lambda p, l: strip(l), q)
        ckpt.save(stripped, str(tmp_path), 0)
        assert ckpt.load_policy(str(tmp_path)) is None
        restored, _ = ckpt.restore(str(tmp_path))
        leaf = restored["layers"]["attn"]["q"]["kernel"]
        assert isinstance(leaf, LutqState) and leaf.sid is None


class TestSerialization:
    def test_json_roundtrip(self):
        for pol in (paper_default(), serving_aggressive(), mixed_paper(),
                    uniform(QuantSpec(bits=3, prune_frac=0.25))):
            s = pol.to_json()
            back = QuantPolicy.from_json(s)
            assert back == pol
            # and it is real JSON
            assert json.loads(s)["name"] == pol.name

    def test_get_policy_presets_and_json(self):
        assert get_policy("serving_aggressive").name == "serving_aggressive"
        assert get_policy("paper_default").name == "paper_default"
        u = get_policy("uniform:2:pow2")
        assert u.is_uniform and u.rules[0].spec.bits == 2
        assert u.rules[0].spec.constraint == "pow2"
        inline = get_policy(mixed_paper().to_json())
        assert inline == mixed_paper()
        with pytest.raises(ValueError):
            get_policy("nonsense")

    def test_as_policy_normalization(self):
        assert as_policy(None) is None
        p = as_policy(LUTQ_4BIT)
        assert isinstance(p, QuantPolicy) and p.is_uniform
        assert as_policy(p) is p

    def test_spec_from_dict_rejects_unknown_fields(self):
        from repro.core.spec import spec_from_dict
        with pytest.raises(ValueError):
            spec_from_dict({"bits": 4, "bogus": 1})


class TestReporting:
    def test_quantized_fraction_on_serve_view(self):
        """Regression: serve_view sets w=None; fraction must count via
        assignments (with pack4 uint8 halving)."""
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        want = quantized_fraction(q)
        got_raw = quantized_fraction(serve_view(q))
        got_packed = quantized_fraction(serve_view(q, pack4=True))
        assert got_raw == pytest.approx(want)
        assert got_packed == pytest.approx(want)

    def test_effective_bits(self):
        q = quantize_tree(_params(), QuantSpec(bits=4, min_size=1024))
        assert effective_bits(q) == pytest.approx(4.0)
        pol = QuantPolicy(rules=(
            QuantRule("*/attn/*", LUTQ_4BIT, min_size=512),
            QuantRule("*/mlp/*", LUTQ_2BIT, min_size=512),
        ))
        q2 = quantize_tree(_params(), pol)
        eb = effective_bits(q2)
        assert 2.0 < eb < 4.0

    def test_rule_breakdown_counts_everything(self):
        pol = mixed_paper()
        q = quantize_tree(_params(), pol)
        rows = rule_breakdown(serve_view(q, pack4=True, policy=pol), pol)
        total = sum(r["n_params"] for r in rows)
        want = sum((l.w.size if isinstance(l, LutqState) else l.size)
                   for _, l in tree_paths(q) if l is not None)
        assert total == want
        by_name = {r["rule"]: r for r in rows}
        assert by_name["attn-4bit-pow2"]["n_quantized"] > 0
        assert by_name["mlp-ternary"]["index_bits"] == 2
        assert by_name["first-layer-fp"]["n_quantized"] == 0


class TestPruneMaskSelection:
    def test_topk_matches_full_sort(self):
        from repro.core.lutq import _prune_mask
        w = jax.random.normal(jax.random.PRNGKey(3), (1000,))
        for frac in (0.0, 0.1, 0.5, 0.9):
            got = np.asarray(_prune_mask(w, frac))
            flat = np.abs(np.asarray(w).ravel())
            k = int(round(frac * flat.size))
            if k <= 0:
                want = np.zeros_like(got)
            else:
                thresh = np.sort(flat)[k - 1]
                want = np.abs(np.asarray(w)) <= thresh
            np.testing.assert_array_equal(got, want)
