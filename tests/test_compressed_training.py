"""Integration: error-feedback int8 gradient compression inside a real
LUT-Q train loop — convergence must track the uncompressed run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.policy import merge_trainable, split_trainable
from repro.core.spec import QuantSpec
from repro.data.synthetic import MarkovLM
from repro.distributed.compress import ef_int8_transform, init_ef_state
from repro.models import api
from repro.models.reduce import reduced
from repro.optim.optimizers import adamw, clip_by_global_norm


def _train(compress: bool, steps=40, seed=0):
    cfg = reduced(get_config("h2o-danube-1.8b")).replace(
        vocab=48, quant=QuantSpec(bits=4, min_size=512), act_bits=8)
    params, axes = api.init(jax.random.PRNGKey(seed), cfg)
    params = api.quantize(params, cfg, axes)
    trainable, static = split_trainable(params)
    opt = adamw(2e-3)
    opt_state = opt.init(trainable)
    ef = init_ef_state(trainable) if compress else None

    @jax.jit
    def step(trainable, static, opt_state, ef, n, batch):
        def loss_fn(t):
            return api.loss_fn(merge_trainable(t, static), cfg, batch)[0]

        loss, g = jax.value_and_grad(loss_fn)(trainable)
        if ef is not None:
            # the compressed-collective arithmetic: what each worker
            # contributes to the DP all-reduce
            g, ef = ef_int8_transform(g, ef)
        g, _ = clip_by_global_norm(g, 1.0)
        trainable, opt_state = opt.update(g, opt_state, trainable, n)
        from repro.core.policy import kmeans_tree
        merged = kmeans_tree(merge_trainable(trainable, static), cfg.quant)
        _, static = split_trainable(merged)
        return trainable, static, opt_state, ef, loss

    lm = MarkovLM(cfg.vocab, seed=1)
    losses = []
    for n in range(steps):
        batch = {k: jnp.asarray(v) for k, v in lm.batch(0, n, 4, 24).items()}
        trainable, static, opt_state, ef, loss = step(
            trainable, static, opt_state, ef, jnp.asarray(n), batch)
        losses.append(float(loss))
    return losses


class TestCompressedTraining:
    @pytest.mark.slow
    def test_ef_int8_converges_like_fp(self):
        base = _train(False)
        comp = _train(True)
        assert comp[-1] < comp[0] * 0.8, comp[::10]
        # compressed run tracks the exact run within 15%
        assert abs(comp[-1] - base[-1]) / base[-1] < 0.15, (base[-1], comp[-1])
