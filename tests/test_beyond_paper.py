"""Tests for the paper's stated future directions, implemented here:
distillation-compatible LUT-Q training and learned-clip activation
quantization (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.actquant import learned_clip_fake_quant
from repro.core.distill import kd_loss, make_distill_loss
from repro.core.policy import merge_trainable, split_trainable
from repro.core.spec import QuantSpec
from repro.configs import get_config
from repro.data.synthetic import MarkovLM
from repro.models import api
from repro.models.lm import lm_forward
from repro.models.reduce import reduced
from repro.optim.optimizers import adamw
from repro.optim.train_state import init_train_state, make_train_step, state_flat


class TestDistill:
    def test_kd_loss_zero_for_identical(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
        assert abs(float(kd_loss(logits, logits))) < 1e-5

    def test_kd_loss_positive_and_orders(self):
        l1 = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
        near = l1 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), l1.shape)
        far = l1 + 2.0 * jax.random.normal(jax.random.PRNGKey(2), l1.shape)
        assert 0 < float(kd_loss(near, l1)) < float(kd_loss(far, l1))

    @pytest.mark.slow
    def test_distilled_lutq_student_trains(self):
        """2-bit student distilling from an fp32 teacher: loss decreases
        and teacher receives no gradient."""
        cfg = reduced(get_config("h2o-danube-1.8b")).replace(
            vocab=32, quant=None, act_bits=32)
        teacher, _ = api.init(jax.random.PRNGKey(0), cfg)
        s_cfg = cfg.replace(quant=QuantSpec(bits=2, min_size=512), act_bits=8)
        student, axes = api.init(jax.random.PRNGKey(1), s_cfg)
        student = api.quantize(student, s_cfg, axes)

        loss_fn = make_distill_loss(lm_forward, teacher, cfg, alpha=0.5)
        opt = adamw(2e-3)
        state = state_flat(init_train_state(student, opt))
        step = jax.jit(make_train_step(s_cfg, loss_fn, opt))
        lm = MarkovLM(32, seed=3)
        losses = []
        for n in range(30):
            batch = {k: jnp.asarray(v) for k, v in lm.batch(0, n, 4, 16).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]
        assert np.isfinite(losses[-1])


class TestLearnedClip:
    def test_values_within_clip(self):
        x = jnp.linspace(-10, 10, 101)
        q = learned_clip_fake_quant(x, jnp.asarray(2.0), bits=8)
        assert float(jnp.max(jnp.abs(q))) <= 2.0 + 1e-6

    def test_levels(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 3
        q = learned_clip_fake_quant(x, jnp.asarray(1.5), bits=4)
        assert len(np.unique(np.asarray(q))) <= 16

    def test_alpha_learns_to_cover_range(self):
        """Training alpha on reconstruction error should widen a
        too-small clip toward the data range."""
        x = jax.random.normal(jax.random.PRNGKey(1), (4096,)) * 2.0
        alpha = jnp.asarray(0.25)

        def loss(a):
            return jnp.mean((learned_clip_fake_quant(x, a, bits=8) - x) ** 2)

        l0 = float(loss(alpha))
        for _ in range(200):
            alpha = alpha - 0.05 * jax.grad(loss)(alpha)
        assert float(alpha) > 0.25 and float(loss(alpha)) < l0 * 0.2

    def test_bits32_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (64,))
        np.testing.assert_array_equal(
            np.asarray(learned_clip_fake_quant(x, jnp.asarray(1.0), 32)),
            np.asarray(x))
