"""Multiplier-less edge serving: the ``serving_pow2`` preset end-to-end.

    PYTHONPATH=src python examples/serve_edge.py [--arch h2o-danube-1.8b]

Builds a reduced model under the ``serving_pow2`` policy (fp
embeddings/readout, 4-bit pow2-constrained body on the shift-add
backend, frozen 8-bit activations), calibrates activation scales from
one short batch, then:

1. prints the per-leaf backend manifest (every body matmul should
   resolve ``pow2`` with ``act_frozen``) and the sign+exponent-plane
   storage win (`memory.pow2_layer_bits`);
2. prints the per-layer op budget — integer adds + bit-shifts instead
   of MACs, fp multiplies only at the epilogue scale
   (`memory.affine_shift_ops`);
3. lowers a compiled prefill to StableHLO and runs the multiply audit
   (`kernels.audit`) proving the quantized matmul path contains **no**
   floating-point multiplications;
4. generates a few tokens and checks the shift-add path is
   token-identical to the integer decode oracle.

See docs/multiplierless.md for the encoding and kernel math.
"""
import argparse
import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import memory
from repro.core.lutq import LutqState
from repro.core.policy import lutq_weight_count
from repro.nn.tree import tree_paths
from repro.core.rules import serving_pow2
from repro.kernels import audit
from repro.models import api
from repro.models.reduce import reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b", choices=list_archs())
    ap.add_argument("--calib-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(
        quant=serving_pow2(), act_bits=8, remat=False)
    rng = np.random.default_rng(0)
    calib = {"tokens": rng.integers(0, cfg.vocab, size=(2, args.calib_len))
             .astype(np.int32)}

    print(f"[edge] {cfg.name}: serving_pow2 preset, calibrating on "
          f"{calib['tokens'].shape} tokens")
    sv, axes, man = api.serve_state(jax.random.PRNGKey(0), cfg,
                                    with_manifest=True, calib_batch=calib)

    # 1. manifest + storage --------------------------------------------
    print("\nper-leaf backend manifest:")
    for path, rec in sorted(man.items()):
        print(f"  {path:42s} backend={rec['backend']:6s} "
              f"encoding={rec['encoding']:5s} K={rec['K']:2d} "
              f"act_frozen={rec['act_frozen']}")

    dense_bits = q_bits = 0
    for path, leaf in tree_paths(sv):
        if not isinstance(leaf, LutqState):
            continue
        n = lutq_weight_count(leaf)
        K = int(leaf.d.shape[-1])
        dense_bits += memory.dense_layer_bits(n)
        q_bits += memory.pow2_layer_bits(n, K,
                                         act_pair=leaf.act is not None)
    if q_bits:
        print(f"\nquantized-leaf storage: {q_bits/8/2**20:.3f} MiB pow2 "
              f"vs {dense_bits/8/2**20:.3f} MiB f32 "
              f"({dense_bits/q_bits:.1f}x)")

    # 2. per-layer op budget -------------------------------------------
    print("\nper-layer multiply/shift/add budget (one token):")
    tot = {"adds": 0, "shifts": 0, "fp_mults": 0}
    dense_mults = 0
    for path, leaf in tree_paths(sv):
        if not isinstance(leaf, LutqState) or leaf.a.ndim < 2:
            continue
        kin, nout = int(leaf.a.shape[-2]), int(leaf.a.shape[-1])
        if leaf.a.dtype == np.uint8:
            kin *= 2  # packed rows
        stack = int(np.prod(leaf.a.shape[:-2], dtype=np.int64))
        ops = memory.affine_shift_ops(nout, kin, int(leaf.d.shape[-1]))
        for k in tot:
            tot[k] += ops[k] * stack
        dense_mults += kin * nout * stack
        print(f"  {'/'.join(path):42s} adds={ops['adds']*stack:>10d} "
              f"shifts={ops['shifts']*stack:>7d} "
              f"fp_mults={ops['fp_mults']*stack:>7d}")
    print(f"  {'(total)':42s} adds={tot['adds']:>10d} "
          f"shifts={tot['shifts']:>7d} fp_mults={tot['fp_mults']:>7d}")
    if tot["fp_mults"]:
        print(f"  fp multiplies: {dense_mults} dense -> {tot['fp_mults']} "
              f"epilogue-only ({dense_mults/tot['fp_mults']:.0f}x fewer)")

    # 3. compile-time multiply audit -----------------------------------
    toks = calib["tokens"][:1]
    report = audit.audit_multiplierless(
        lambda p, t: api.prefill(p, cfg, {"tokens": t})[0],
        sv, toks, params=sv)
    n_int = len(report["int_dots"])
    bmuls = sum(m["elems"] for m in report["fp_multiplies"])
    print(f"\nStableHLO multiply audit of compiled prefill: PASS "
          f"({n_int} integer dots, 0 fp ops on quantized weight shapes, "
          f"{bmuls} fp multiply elems outside them — epilogue scales, "
          f"norms, fp-by-policy layers)")

    # 4. shift-add vs integer-oracle token parity ----------------------
    from repro.runtime.serving import generate
    batch = {"tokens": toks}
    ys_auto = generate(sv, cfg, batch, steps=args.gen, backend="auto")
    ys_ref = generate(sv, cfg, batch, steps=args.gen, backend="decode")
    same = bool(np.array_equal(np.asarray(ys_auto), np.asarray(ys_ref)))
    print(f"generate({args.gen} tokens) shift-add vs decode oracle: "
          f"{'token-identical' if same else 'MISMATCH'}")
    if not same:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
