"""End-to-end driver: train a ~100M-param LUT-Q LM for a few hundred
steps on the byte-level corpus, with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_e2e.py            # ~100M, 300 steps
    PYTHONPATH=src python examples/train_e2e.py --tiny     # CI-sized

The model is the danube family (GQA + SWA) scaled to ~100M params;
weights train under 4-bit pow2 LUT-Q with 8-bit activations — the
paper's full recipe end to end, on the framework's own source tree as
the corpus.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.spec import QuantSpec
from repro.data.text import byte_batch, default_corpus
from repro.models import api
from repro.optim.optimizers import adamw, cosine_schedule
from repro.optim.train_state import init_train_state, make_train_step, state_flat
from repro.runtime.loop import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/lutq_e2e_ckpt")
    args = ap.parse_args()

    base = get_config("h2o-danube-1.8b")
    if args.tiny:
        cfg = base.replace(
            n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
            d_ff=256, vocab=256, window=64, dtype=jnp.float32,
            attn_q_block=64, attn_kv_block=64,
            quant=QuantSpec(bits=4, constraint="pow2", min_size=1024),
            act_bits=8)
        steps, batch, seq = args.steps or 40, 4, 64
    else:
        # ~100M params: 12L x d640 (GQA 8/2, SWA 256) + byte vocab
        cfg = base.replace(
            n_layers=12, d_model=640, n_heads=8, n_kv_heads=2, head_dim=80,
            d_ff=1920, vocab=256, window=256, dtype=jnp.float32,
            attn_q_block=128, attn_kv_block=128,
            quant=QuantSpec(bits=4, constraint="pow2", min_size=4096),
            act_bits=8)
        steps, batch, seq = args.steps or 300, 4, 256

    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    params = api.quantize(params, cfg, axes)
    n = sum(x.size for x in jax.tree.leaves(params) if hasattr(x, "size"))
    print(f"[e2e] {n/1e6:.1f}M parameter slots (incl. LUT-Q state), "
          f"{steps} steps, batch {batch}x{seq}")

    opt = adamw(cosine_schedule(3e-3, 20, steps))
    state = state_flat(init_train_state(params, opt))
    step_fn = jax.jit(make_train_step(cfg, api.loss_fn, opt))

    corpus = default_corpus(str(Path(__file__).resolve().parent.parent))
    print(f"[e2e] corpus: {len(corpus)/1e6:.2f}M bytes of this repo")

    def make_batch(s):
        b = byte_batch(corpus, s, batch, seq, seed=1)
        return {k: jnp.asarray(v) for k, v in b.items()}

    loop = TrainLoop(step_fn, make_batch, ckpt_dir=args.ckpt_dir,
                     ckpt_every=100, log_every=10)
    state, step = loop.run(state, steps)
    losses = [h["loss"] for h in loop.history]
    if losses:
        print(f"[e2e] byte-level CE {losses[0]:.3f} -> {losses[-1]:.3f} "
              f"({step} steps; resume-capable checkpoint in {args.ckpt_dir})")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
