"""Quickstart: LUT-Q in 60 lines.

Quantize a small LM with a learned 4-bit power-of-two dictionary, train
it with the paper's per-minibatch k-means refresh, and export the
multiplier-less deployment form (dictionary + assignments only).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.policy import serve_view
from repro.core.spec import QuantSpec
from repro.data.synthetic import MarkovLM
from repro.models import api
from repro.models.reduce import reduced
from repro.optim.optimizers import adamw
from repro.optim.train_state import init_train_state, make_train_step, state_flat

# 1. pick an architecture (any of the 10 registered ones) at CPU scale
cfg = reduced(get_config("h2o-danube-1.8b")).replace(
    vocab=64,
    quant=QuantSpec(bits=4, constraint="pow2", kmeans_iters=1, min_size=512),
    act_bits=8,  # paper: uniform 8-bit activations
)

# 2. init + install LUT-Q state on every eligible weight
params, axes = api.init(jax.random.PRNGKey(0), cfg)
params = api.quantize(params, cfg, axes)

# 3. train: steps 1-4 of the paper's algorithm run inside train_step
opt = adamw(2e-3)
state = state_flat(init_train_state(params, opt))
step = jax.jit(make_train_step(cfg, api.loss_fn, opt))

lm = MarkovLM(cfg.vocab, seed=1)
for n in range(80):
    batch = {k: jnp.asarray(v) for k, v in lm.batch(0, n, 8, 32).items()}
    state, metrics = step(state, batch)
    if n % 20 == 0:
        print(f"step {n:3d} loss {float(metrics['loss']):.3f} "
              f"(floor ~{lm.entropy_floor():.2f})")

# 4. inspect a learned dictionary: sorted, powers of two
from repro.core.lutq import LutqState
from repro.nn.tree import tree_paths

final = {"trainable": state["trainable"], "static": state["static"]}
from repro.core.policy import merge_trainable
params = merge_trainable(state["trainable"], state["static"])
for path, leaf in tree_paths(params):
    if isinstance(leaf, LutqState):
        d = np.asarray(leaf.d).ravel()[:8]
        print(f"dictionary at {'/'.join(path)}: {d}")
        break

# 5. export the deployment form: no fp32 masters, just (d, A) — with
#    4-bit packing this is the paper's N*ceil(log2 K) storage, literally
deploy = serve_view(params, pack4=True)
n_bytes = sum(x.nbytes for x in jax.tree.leaves(deploy) if x is not None)
n_fp = sum(x.w.nbytes if isinstance(x, LutqState) else x.nbytes
           for _, x in tree_paths(params) if x is not None)
print(f"deployment size {n_bytes/2**20:.2f} MiB vs fp32 {n_fp/2**20:.2f} MiB "
      f"({n_fp/n_bytes:.1f}x smaller)")

# 6. mixed precision: a QuantPolicy maps path patterns to specs with
#    first-match-wins semantics — here fp embeddings + excluded head,
#    4-bit pow2 attention, 2-bit ternary MLPs (the paper's actual
#    protocol; see docs/quant_policy.md for the rule syntax)
from repro.core.policy import format_breakdown, rule_breakdown
from repro.core.rules import QuantPolicy, QuantRule
from repro.core.spec import LUTQ_4BIT_POW2, TERNARY_SCALED

policy = QuantPolicy(rules=(
    QuantRule("re:(^|/)table$", None, name="embed-fp"),
    QuantRule("lm_head/*", None, name="head-fp"),
    QuantRule("*/attn/*", LUTQ_4BIT_POW2, min_size=512, name="attn-4bit"),
    QuantRule("*/mlp/*", TERNARY_SCALED, min_size=512, name="mlp-ternary"),
    QuantRule("*", LUTQ_4BIT_POW2, min_size=512, name="rest-4bit"),
), name="quickstart_mixed")

mixed_cfg = cfg.replace(quant=policy)  # ModelConfig.quant takes either form
mparams, maxes = api.init(jax.random.PRNGKey(0), mixed_cfg)
mparams = api.quantize(mparams, mixed_cfg, maxes)
mdeploy = serve_view(mparams, pack4=True, policy=policy)
print("\nmixed-precision breakdown (per rule):")
print(format_breakdown(rule_breakdown(mdeploy, policy)))
