"""Simultaneous pruning + quantization (paper Fig. 2) on a small CNN.

LUT-Q's pruning mode pins one dictionary entry to zero and forces the
smallest-magnitude weights onto it — prune fraction and bitwidth sweep
in one training mechanism.

    PYTHONPATH=src python examples/prune_and_quantize.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))

import jax
import numpy as np

from cifar_table import train_one
from repro.core.spec import QuantSpec


def main():
    base = train_one(None, steps=150)
    print(f"fp32 baseline error: {base:.1f}%")
    for prune in (0.5, 0.7):
        err = train_one(QuantSpec(bits=2), prune=prune, steps=150)
        print(f"2-bit, {int(prune*100)}% pruned: {err:.1f}% "
              f"(delta {err-base:+.1f}%)")
    # verify the pruned fraction is real: decode a kernel and count zeros
    from repro.core.lutq import LutqState, decode_any
    from repro.core.policy import quantize_tree
    from repro.models.resnet import init_resnet20
    params, _ = init_resnet20(jax.random.PRNGKey(0), widths=(8, 16, 32), blocks=1)
    q = quantize_tree(params, QuantSpec(bits=2, prune_frac=0.7, min_size=256))
    from repro.nn.tree import tree_paths
    for path, leaf in tree_paths(q):
        if isinstance(leaf, LutqState):
            w = np.asarray(decode_any(leaf.d, leaf.a))
            print(f"{'/'.join(path)}: {100*(w == 0).mean():.0f}% zeros")
            break


if __name__ == "__main__":
    main()
