"""Batched serving with LUT-Q deployment weights (dictionary + packed
assignments, no fp32 masters) — a ragged queue of prompts served by the
continuous-batching engine with the int8 KV cache, on the **paged** KV
path with a shared system prompt so the prefix cache has something to
hit.

    PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-1.6b]

Each request is ``--sys-len`` shared system-prompt tokens plus a unique
tail. On paged-capable families the engine maps the shared prompt's KV
pages once and every later request reuses them (prefix-cache hits, no
recompute); the run prints the hit rate and pages-in-use alongside
throughput. Families without a growing KV sequence (rwkv, zamba, MLA)
silently serve the same workload on the slot pool — same Engine API,
same stats dict as ``python -m repro.launch.serve --engine``.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.policy import serve_view
from repro.core.spec import QuantSpec
from repro.launch.serve import format_engine_stats, run_engine
from repro.models import api
from repro.models.reduce import reduced


def shared_prefix_requests(cfg, n, *, sys_len, tail_len, gen, seed=0):
    """``n`` requests = one shared system prompt + per-request tails:
    the workload shape where prefix sharing pays (every request after
    the first maps the system prompt's full KV pages instead of
    recomputing them)."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, cfg.vocab, size=(sys_len,)).astype(np.int32)
    reqs = []
    for _ in range(n):
        tail = rng.integers(
            0, cfg.vocab,
            size=(int(rng.integers(1, tail_len + 1)),)).astype(np.int32)
        reqs.append({"tokens": np.concatenate([sys_prompt, tail]),
                     "max_new": int(rng.integers(max(1, gen // 4), gen + 1))})
    return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b", choices=list_archs())
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--queue", type=int, default=12)
    ap.add_argument("--sys-len", type=int, default=16,
                    help="shared system-prompt tokens (page-aligned at "
                         "the default --page-size)")
    ap.add_argument("--tail-len", type=int, default=8,
                    help="max unique tail tokens per request")
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--kv-pages", type=int, default=24,
                    help="page-pool size for paged-capable families")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=8)
    if cfg.family in ("dense", "moe", "vlm") and not cfg.use_mla:
        cfg = cfg.replace(kv_cache_bits=8)  # §Perf cell-C optimization

    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    qparams = api.quantize(params, cfg, axes)
    deploy = serve_view(qparams, pack4=True)

    fp = sum(x.nbytes for x in jax.tree.leaves(params) if x is not None)
    dq = sum(x.nbytes for x in jax.tree.leaves(deploy) if x is not None)
    print(f"[serve] {cfg.name}: deploy {dq/2**20:.2f} MiB "
          f"(fp32 {fp/2**20:.2f} MiB, {fp/dq:.1f}x)")

    reqs = shared_prefix_requests(cfg, args.queue, sys_len=args.sys_len,
                                  tail_len=args.tail_len, gen=args.gen)
    prompt_len = args.sys_len + args.tail_len
    stats = run_engine(deploy, cfg, capacity=args.max_batch,
                       n_requests=args.queue, prompt_len=prompt_len,
                       gen=args.gen, kv_pages=args.kv_pages,
                       page_size=args.page_size, requests=reqs)
    print(format_engine_stats(stats))
    if stats.get("paged"):
        print(f"[serve] shared system prompt: {args.sys_len} tokens -> "
              f"{stats['prefix_hit_rate']*100:.0f}% of queried prompt "
              f"pages served from the prefix cache")
    else:
        print(f"[serve] {cfg.family} keeps its recurrent/latent decode "
              f"state on the slot pool (paged KV targets growing "
              f"attention caches)")


if __name__ == "__main__":
    main()
