"""Batched serving with LUT-Q deployment weights (dictionary + packed
assignments, no fp32 masters) — a ragged queue of prompts served by the
continuous-batching slot-pool engine with the int8 KV cache.

    PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-1.6b]

Each request is prefilled at its own length through the real prefill
path (the fused LUT-Q kernel backends included), spliced into a free
decode slot, and retired as soon as it finishes — the decode batch
stays full instead of lock-stepping on the longest prompt. Prints the
same stats dict as ``python -m repro.launch.serve --engine``.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config, list_archs
from repro.core.policy import serve_view
from repro.core.spec import QuantSpec
from repro.launch.serve import format_engine_stats, run_engine
from repro.models import api
from repro.models.reduce import reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b", choices=list_archs())
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--queue", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=8)
    if cfg.family in ("dense", "moe", "vlm") and not cfg.use_mla:
        cfg = cfg.replace(kv_cache_bits=8)  # §Perf cell-C optimization

    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    qparams = api.quantize(params, cfg, axes)
    deploy = serve_view(qparams, pack4=True)

    fp = sum(x.nbytes for x in jax.tree.leaves(params) if x is not None)
    dq = sum(x.nbytes for x in jax.tree.leaves(deploy) if x is not None)
    print(f"[serve] {cfg.name}: deploy {dq/2**20:.2f} MiB "
          f"(fp32 {fp/2**20:.2f} MiB, {fp/dq:.1f}x)")

    stats = run_engine(deploy, cfg, capacity=args.max_batch,
                       n_requests=args.queue, prompt_len=args.prompt_len,
                       gen=args.gen)
    print(format_engine_stats(stats))


if __name__ == "__main__":
    main()
