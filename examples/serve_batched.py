"""Batched serving with LUT-Q deployment weights (dictionary + packed
assignments, no fp32 masters) — prefill a batch of prompts, then decode
tokens with the int8 KV cache.

    PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-1.6b]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.policy import serve_view
from repro.core.spec import QuantSpec
from repro.models import api
from repro.models.reduce import reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(
        quant=QuantSpec(bits=4, min_size=1024), act_bits=8)
    if cfg.family in ("dense", "moe", "vlm") and not cfg.use_mla:
        cfg = cfg.replace(kv_cache_bits=8)  # §Perf cell-C optimization

    params, axes = api.init(jax.random.PRNGKey(0), cfg)
    qparams = api.quantize(params, cfg, axes)
    deploy = serve_view(qparams, pack4=True)

    fp = sum(x.nbytes for x in jax.tree.leaves(params) if x is not None)
    dq = sum(x.nbytes for x in jax.tree.leaves(deploy) if x is not None)
    print(f"[serve] {cfg.name}: deploy {dq/2**20:.2f} MiB "
          f"(fp32 {fp/2**20:.2f} MiB, {fp/dq:.1f}x)")

    B, P = args.batch, 16
    max_len = P + args.gen
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)

    # decode loop against a preallocated max_len cache: write the prompt
    # through decode steps (simple; production prefill path also exists)
    decode = jax.jit(lambda p, t, c: api.decode_step(p, cfg, t, c))
    cache = api.init_cache(cfg, B, max_len, src_len=max_len)
    tok = toks[:, :1]
    t0 = time.perf_counter()
    generated = []
    for i in range(P + args.gen - 1):
        logits, cache = decode(deploy, tok, cache)
        if i + 1 < P:
            tok = toks[:, i + 1:i + 2]  # teacher-force the prompt
        else:
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = np.asarray(jnp.concatenate(generated, 1))
    print(f"[serve] {B} streams x {len(generated)} new tokens in {dt:.2f}s "
          f"({B*len(generated)/dt:.1f} tok/s) | first stream: {out[0][:10]}")


if __name__ == "__main__":
    main()
